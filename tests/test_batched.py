"""Scenario-axis batched fast path (ISSUE 3): agreement with the
per-scenario oracle on every built-in grid, array-valued collective
models, the frontier grid + scaled-preset grammar, streaming emission,
and the priority/steady-state/filter bugfix pass."""
import dataclasses
import json

import numpy as np
import pytest
from hypothesis import given, settings
from strategies import wfbp_layer_times

from repro.core import analytical as A
from repro.core import hardware as HW
from repro.core.batched import eval_scenarios
from repro.core.dag import IterationCosts, build_ssgd_dag
from repro.core.policies import CAFFE_MPI, PRIORITY, get_policy
from repro.core.scenarios import (Scenario, ScenarioGrid, default_grid,
                                  frontier_grid, mixed_grid,
                                  normalize_interconnect)
from repro.core.simulator import NET_CHANNEL, simulate_policy, simulate_steady
from repro.core.sweep import (_fast_eval, iter_rows, stream_csv, stream_json,
                              sweep)

NUMERIC = ("iteration_time_s", "samples_per_sec", "speedup",
           "t_comm_s", "t_comp_s")
LABELS = ("workload", "cluster", "n_workers", "policy", "collective",
          "interconnect", "batch_per_gpu", "method")


def assert_rows_agree(batched_rows, oracle_rows, rel=1e-9):
    assert len(batched_rows) == len(oracle_rows)
    for a, b in zip(batched_rows, oracle_rows):
        assert {k: a[k] for k in LABELS} == {k: b[k] for k in LABELS}
        for k in NUMERIC:
            assert a[k] == pytest.approx(b[k], rel=rel), (a, k)


class TestBatchedAgreement:
    """ISSUE-3 acceptance: the batched kernel agrees with the
    per-scenario reference `_fast_eval` to <= 1e-9 relative on the
    default, mixed and frontier grids."""

    @pytest.mark.parametrize("make_grid", [default_grid, mixed_grid],
                             ids=["default", "mixed"])
    def test_full_grid_agreement(self, make_grid):
        grid = make_grid()
        batched = sweep(grid)
        oracle = [_fast_eval(s) for s in grid.expand()]
        assert_rows_agree(batched.rows, oracle)

    def test_frontier_grid_agreement_sampled_plus_sweep(self):
        grid = frontier_grid()
        batched = sweep(grid)
        assert batched.n_simulated == 0
        assert batched.n_timeline > 0          # bucket-size + priority axis
        scenarios = grid.expand()
        assert len(batched) == len(scenarios) >= 20_000
        # oracle every 37th scenario (coprime stride covers every axis
        # value) — the full per-scenario pass is benchmarked, not
        # tested.  Closed-form rows check against _fast_eval (<=1e-9);
        # timeline rows against the event-driven simulator (<=1e-6),
        # sampled sparser because each oracle call list-schedules a DAG.
        idx = [i for i in range(0, len(scenarios), 37)
               if batched.rows[i]["method"] == "analytical"]
        assert idx
        assert_rows_agree([batched.rows[i] for i in idx],
                          [_fast_eval(scenarios[i]) for i in idx])
        from repro.core.sweep import _sim_eval
        tl_idx = [i for i in range(0, len(scenarios), 331)
                  if batched.rows[i]["method"] == "timeline"]
        assert tl_idx
        for i in tl_idx:
            assert batched.rows[i]["iteration_time_s"] == pytest.approx(
                _sim_eval(scenarios[i])["iteration_time_s"], rel=1e-6), \
                scenarios[i].label()

    def test_batched_false_uses_reference_path(self):
        grid = ScenarioGrid(workloads=("alexnet",), worker_counts=(4,),
                            policies=("caffe-mpi",))
        assert_rows_agree(sweep(grid, batched=False).rows,
                          [_fast_eval(s) for s in grid.expand()], rel=0)

    def test_row_order_matches_expand(self):
        grid = ScenarioGrid(workloads=("alexnet", "googlenet"),
                            worker_counts=(2, 8), policies=("naive", "mxnet"),
                            collectives=("ring", "tree"))
        rows = sweep(grid).rows
        for row, s in zip(rows, grid.expand()):
            assert (row["workload"], row["cluster"], row["n_workers"],
                    row["policy"], row["collective"]) == \
                (s.workload, s.cluster, s.n_workers, s.policy, s.collective)

    def test_timeline_rows_interleaved_in_order(self):
        grid = ScenarioGrid(workloads=("alexnet",),
                            clusters=("v100-nvlink-ib",), worker_counts=(4,),
                            policies=("caffe-mpi", "bucketed-25mb",
                                      "priority"))
        r = sweep(grid)
        assert r.n_analytical == 1 and r.n_timeline == 2 \
            and r.n_simulated == 0
        assert [row["method"] for row in r.rows] == \
            ["analytical", "timeline", "timeline"]
        # the timeline rows agree with the event-driven oracle
        from repro.core.sweep import _sim_eval
        for row, s in zip(r.rows, grid.expand()):
            if row["method"] == "timeline":
                assert row["iteration_time_s"] == pytest.approx(
                    _sim_eval(s)["iteration_time_s"], rel=1e-6)

    def test_simulator_rows_interleaved_in_order(self):
        # policies with neither closed nor timeline form still fall
        # back to the simulator, interleaved in grid order
        from repro.core import policies as P
        from repro.core.policies import Policy
        weird = Policy("_unstudied", overlap_comm=True)   # no io overlap
        P.ALL_POLICIES["_unstudied"] = weird
        try:
            grid = ScenarioGrid(workloads=("alexnet",),
                                clusters=("v100-nvlink-ib",),
                                worker_counts=(4,),
                                policies=("caffe-mpi", "_unstudied"))
            r = sweep(grid)
            assert r.n_analytical == 1 and r.n_timeline == 0 \
                and r.n_simulated == 1
            assert [row["method"] for row in r.rows] == \
                ["analytical", "simulated"]
            from repro.core.sweep import _sim_eval
            for row, s in zip(r.rows, grid.expand()):
                if row["method"] == "simulated":
                    assert row["iteration_time_s"] == pytest.approx(
                        _sim_eval(s)["iteration_time_s"])
        finally:
            del P.ALL_POLICIES["_unstudied"]

    def test_eval_scenarios_list_front_end(self):
        scenarios = ScenarioGrid(workloads=("resnet50",),
                                 worker_counts=(1, 16),
                                 policies=("cntk", "tensorflow")).expand()
        assert_rows_agree(eval_scenarios(scenarios),
                          [_fast_eval(s) for s in scenarios])

    def test_eval_scenarios_accepts_timeline_policies(self):
        from repro.core.sweep import _sim_eval
        scenarios = [Scenario("alexnet", "v100-nvlink-ib", 4,
                              "bucketed-25mb"),
                     Scenario("alexnet", "v100-nvlink-ib", 4, "priority")]
        rows = eval_scenarios(scenarios)
        assert [r["method"] for r in rows] == ["timeline", "timeline"]
        for row, s in zip(rows, scenarios):
            assert row["iteration_time_s"] == pytest.approx(
                _sim_eval(s)["iteration_time_s"], rel=1e-6)

    def test_eval_scenarios_rejects_unbatchable_policies(self):
        from repro.core import policies as P
        from repro.core.policies import Policy
        P.ALL_POLICIES["_unstudied"] = Policy("_unstudied", h2d_early=True)
        try:
            with pytest.raises(ValueError, match="batched"):
                eval_scenarios([Scenario("alexnet", "v100-nvlink-ib", 4,
                                         "_unstudied")])
        finally:
            del P.ALL_POLICIES["_unstudied"]

    def test_empty_grid_and_empty_iterable(self):
        assert len(sweep(ScenarioGrid(workloads=()))) == 0
        assert len(sweep(iter([]))) == 0

    def test_sweep_accepts_plain_scenario_list(self):
        scenarios = [Scenario("alexnet", "k80-pcie-10gbe", 8, "caffe-mpi"),
                     Scenario("alexnet", "k80-pcie-10gbe", 8, "priority")]
        r = sweep(scenarios)
        assert [row["method"] for row in r.rows] == ["analytical",
                                                     "timeline"]
        assert r.n_analytical == 1 and r.n_timeline == 1
        # batched=False pins the per-scenario reference paths instead
        ref = sweep(scenarios, batched=False)
        assert [row["method"] for row in ref.rows] == ["analytical",
                                                       "simulated"]

    def test_batch_override_propagates(self):
        grid = ScenarioGrid(workloads=("resnet50",),
                            clusters=("v100-nvlink-ib",), worker_counts=(4,),
                            policies=("caffe-mpi",), batch_per_gpu=8)
        [row] = sweep(grid).rows
        assert row["batch_per_gpu"] == 8
        assert_rows_agree([row], [_fast_eval(grid.expand()[0])])

    def test_locked_trace_batch_override_rejected(self):
        from repro.traces.format import LayerRecord, Trace
        import repro.traces.bundled as bundled
        from repro.core.workloads import clear_workload_cache

        trace = Trace(network="x", cluster="y", iterations=(
            (LayerRecord(0, "conv1", 10.0, 20.0, 0.0, 4096),),))
        assert trace.batch_per_gpu == 0          # no '# batch:' header
        bundled.BUNDLED_TRACES["_locked_test"] = trace
        try:
            clear_workload_cache()
            grid = ScenarioGrid(workloads=("trace:_locked_test",),
                                clusters=("v100-nvlink-ib",),
                                worker_counts=(2,), policies=("caffe-mpi",),
                                batch_per_gpu=64)
            with pytest.raises(ValueError, match="no recorded batch"):
                sweep(grid)
        finally:
            del bundled.BUNDLED_TRACES["_locked_test"]
            clear_workload_cache()


class TestMeasuredComputeWithoutMeasuredIO:
    """Regression: a trace without a Caffe 'data' layer has measured
    t_f/t_b but no measured t_io — the batched kernel must not gate
    the measured compute terms on measured-I/O presence."""

    def test_agrees_with_oracle(self):
        from repro.traces.format import LayerRecord, Trace
        import repro.traces.bundled as bundled
        from repro.core.workloads import clear_workload_cache

        trace = Trace(network="x", cluster="y", iterations=(
            (LayerRecord(0, "conv1", 30_000.0, 60_000.0, 0.0, 4e6),
             LayerRecord(1, "fc", 10_000.0, 20_000.0, 0.0, 16e6)),),
            batch_per_gpu=16)
        bundled.BUNDLED_TRACES["_no_data_test"] = trace
        try:
            clear_workload_cache()
            scenarios = ScenarioGrid(
                workloads=("trace:_no_data_test",),
                clusters=("v100-nvlink-ib",), worker_counts=(1, 8),
                policies=("caffe-mpi", "naive")).expand()
            rows = eval_scenarios(scenarios)
            assert_rows_agree(rows, [_fast_eval(s) for s in scenarios])
            assert all(r["t_comp_s"] > 0 for r in rows)
        finally:
            del bundled.BUNDLED_TRACES["_no_data_test"]
            clear_workload_cache()


class TestVectorizedWfbpResidual:
    @settings(max_examples=200, deadline=None)
    @given(wfbp_layer_times())
    def test_prefix_max_matches_scalar_loop(self, times):
        t_b, t_c = times
        got = A.non_overlapped_comm_batch(t_b[None, :], t_c[None, :])[0]
        want = A.non_overlapped_comm(list(t_b), list(t_c))
        assert got == pytest.approx(want, rel=1e-12, abs=1e-15)

    def test_zero_padding_is_neutral(self):
        t_b = np.array([[1.0, 2.0, 3.0]])
        t_c = np.array([[0.5, 4.0, 0.0]])
        pad_b = np.pad(t_b, ((0, 0), (0, 5)))
        pad_c = np.pad(t_c, ((0, 0), (0, 5)))
        assert A.non_overlapped_comm_batch(pad_b, pad_c)[0] == \
            pytest.approx(A.non_overlapped_comm_batch(t_b, t_c)[0])

    def test_no_comm_gives_zero(self):
        z = A.non_overlapped_comm_batch(np.ones((3, 4)), np.zeros((3, 4)))
        assert (z == 0.0).all()


class TestArrayValuedCollectives:
    """hardware.py's collective models broadcast per-scenario
    (n, bandwidth, latency) vectors — and agree with the scalar path."""

    def test_ring_tree_match_scalar(self):
        nbytes = np.array([1e4, 1e6, 25e6])
        for n in (1, 2, 5, 16, 64):
            for fn in (HW.ring_allreduce_time, HW.tree_allreduce_time):
                vec = fn(nbytes[None, :], np.array([n])[:, None],
                         10 * HW.GB, 10 * HW.US)
                scal = fn(nbytes, n, 10 * HW.GB, 10 * HW.US)
                np.testing.assert_allclose(vec[0], scal, rtol=0)

    def test_hierarchical_matches_cluster_method(self):
        c = HW.V100_CLUSTER
        nbytes = np.array([4096.0, 1e6, 102e6])
        for n in (1, 2, 4, 6, 16, 32):
            vec = HW.hierarchical_allreduce_time(
                nbytes[None, :], np.array([n])[:, None],
                np.array([c.gpus_per_node])[:, None],
                c.intra.effective_bandwidth, c.intra.latency,
                c.inter.effective_bandwidth, c.inter.latency)
            scal = c.allreduce_time(nbytes, n, "hierarchical")
            np.testing.assert_allclose(vec[0], scal, rtol=0)


class TestHierarchicalDegenerateCases:
    """Satellite: _hierarchical_allreduce_time edge topologies."""

    def test_single_node_equals_flat_intra_ring(self):
        c = HW.V100_CLUSTER
        for n in (2, 3, c.gpus_per_node):
            assert c.allreduce_time(25e6, n, "hierarchical") == \
                pytest.approx(HW.ring_allreduce_time(
                    25e6, n, c.intra.effective_bandwidth, c.intra.latency))

    def test_one_gpu_per_node_equals_flat_inter_ring(self):
        c = dataclasses.replace(HW.V100_CLUSTER, n_nodes=8, gpus_per_node=1)
        for n in (2, 5, 8):
            assert c.allreduce_time(25e6, n, "hierarchical") == \
                pytest.approx(HW.ring_allreduce_time(
                    25e6, n, c.inter.effective_bandwidth, c.inter.latency))

    def test_n_not_divisible_by_gpus_per_node(self):
        c = HW.V100_CLUSTER                    # 4 GPUs/node
        # n=6 -> g=4, nodes=ceil(6/4)=2: intra phase + 2-node inter ring
        t = c.allreduce_time(25e6, 6, "hierarchical")
        intra = 2.0 * ((4 - 1) / 4 * 25e6 / c.intra.effective_bandwidth
                       + 3 * c.intra.latency)
        inter = HW.ring_allreduce_time(25e6 / 4, 2,
                                       c.inter.effective_bandwidth,
                                       c.inter.latency)
        assert t == pytest.approx(intra + inter)

    def test_single_worker_is_free(self):
        assert HW.V100_CLUSTER.allreduce_time(25e6, 1, "hierarchical") == 0.0


class TestScaledPresetGrammar:
    def test_resolve_scales_bandwidth_and_latency(self):
        slot, link = HW.resolve_interconnect_preset("ib-100g@bw2@lat0.25")
        base = HW.INTERCONNECT_PRESETS["ib-100g"][1]
        assert slot == "inter"
        assert link.bandwidth == pytest.approx(2 * base.bandwidth)
        assert link.latency == pytest.approx(0.25 * base.latency)
        assert link.efficiency == base.efficiency

    def test_modifiers_optional_and_order_free(self):
        _, a = HW.resolve_interconnect_preset("10gbe@lat4")
        _, b = HW.resolve_interconnect_preset("10gbe@lat4@bw1")
        assert a.latency == b.latency and a.bandwidth == b.bandwidth

    @pytest.mark.parametrize("bad", [
        "nope@bw2", "ib-100g@speed2", "ib-100g@bw0", "ib-100g@bw-1",
        "ib-100g@bwx"])
    def test_malformed_rejected(self, bad):
        with pytest.raises((KeyError, ValueError)):
            HW.resolve_interconnect_preset(bad)

    def test_scenario_validate_accepts_scaled_preset(self):
        Scenario("alexnet", "k80-pcie-10gbe", 16, "caffe-mpi",
                 interconnect="ib-100g@bw4@lat0.25").validate()
        with pytest.raises(ValueError, match="interconnect"):
            Scenario("alexnet", "k80-pcie-10gbe", 16, "caffe-mpi",
                     interconnect="ib-100g@frob2").validate()

    def test_more_bandwidth_never_slower(self):
        kw = dict(workloads=("resnet50",), clusters=("k80-pcie-10gbe",),
                  worker_counts=(8, 16), policies=("caffe-mpi", "cntk"),
                  collectives=HW.COLLECTIVE_ALGORITHMS)
        slow = sweep(ScenarioGrid(interconnects=("10gbe",), **kw))
        fast = sweep(ScenarioGrid(interconnects=("10gbe@bw4@lat0.25",), **kw))
        for a, b in zip(slow.rows, fast.rows):
            assert b["iteration_time_s"] <= a["iteration_time_s"] + 1e-12


class TestBuiltinGrids:
    @pytest.mark.parametrize("make_grid", [default_grid, mixed_grid,
                                           frontier_grid],
                             ids=["default", "mixed", "frontier"])
    def test_len_equals_expand(self, make_grid):
        g = make_grid()
        assert len(g) == len(g.expand())

    def test_frontier_size_and_axes(self):
        g = frontier_grid()
        assert len(g) >= 20_000
        # every interconnect is a scaled preset that resolves
        for ic in g.interconnects:
            HW.resolve_interconnect_preset(ic)


class TestStreaming:
    def test_stream_csv_matches_buffered(self, tmp_path):
        import csv

        grid = ScenarioGrid(workloads=("alexnet",), worker_counts=(2, 4),
                            policies=("naive", "caffe-mpi", "bucketed-25mb"))
        buffered = sweep(grid)
        p_buf, p_stream = tmp_path / "buf.csv", tmp_path / "stream.csv"
        buffered.to_csv(p_buf)
        summary = stream_csv(grid, p_stream, chunk=2)
        assert summary["n_scenarios"] == len(buffered)
        assert summary["n_analytical"] == buffered.n_analytical
        assert summary["n_simulated"] == buffered.n_simulated
        with open(p_buf) as f:
            want = list(csv.DictReader(f))
        with open(p_stream) as f:
            got = list(csv.DictReader(f))
        assert len(got) == len(want)
        for a, b in zip(got, want):
            assert a["workload"] == b["workload"]
            assert float(a["iteration_time_s"]) == pytest.approx(
                float(b["iteration_time_s"]))

    def test_stream_json_document_shape(self, tmp_path):
        grid = ScenarioGrid(workloads=("googlenet",),
                            clusters=("k80-pcie-10gbe",), worker_counts=(2,),
                            policies=("mxnet",))
        path = tmp_path / "sweep.json"
        stream_json(grid, path)
        doc = json.loads(path.read_text())
        buffered = json.loads(sweep(grid).to_json())
        assert set(doc) == set(buffered)
        assert doc["columns"] == buffered["columns"]
        assert doc["n_scenarios"] == len(doc["rows"]) == 1
        assert doc["rows"][0]["iteration_time_s"] == pytest.approx(
            buffered["rows"][0]["iteration_time_s"])

    def test_stream_both_formats_single_pass(self, tmp_path):
        from repro.core.sweep import stream

        grid = ScenarioGrid(workloads=("alexnet",),
                            clusters=("k80-pcie-10gbe",),
                            worker_counts=(2, 4), policies=("caffe-mpi",))
        p_csv, p_json = tmp_path / "s.csv", tmp_path / "s.json"
        summary = stream(grid, csv_path=p_csv, json_path=p_json)
        assert summary["n_scenarios"] == 2
        doc = json.loads(p_json.read_text())
        assert doc["n_scenarios"] == len(doc["rows"]) == 2
        assert p_csv.read_text().count("\n") == 3       # header + 2 rows
        with pytest.raises(ValueError, match="csv_path"):
            stream(grid)

    def test_iter_rows_is_lazy_and_ordered(self):
        grid = ScenarioGrid(workloads=("alexnet",),
                            clusters=("k80-pcie-10gbe",),
                            worker_counts=(2, 4, 8), policies=("naive",))
        it = iter_rows(grid, chunk=1)
        first = next(it)
        assert first["n_workers"] == 2
        assert [r["n_workers"] for r in it] == [4, 8]


class TestPriorityCommBugfix:
    """Satellite: comm priorities were inverted (layer-L drained
    first); ByteScheduler semantics say earlier-needed layers overtake.
    """

    def _comm_bound_costs(self, L=4):
        # tiny backward, long comms: everything is queued on the net
        # channel nearly at once, so scheduling order is priority-driven
        return IterationCosts(
            t_f=[1e-4] * L, t_b=[1e-4] * L,
            t_c=[0.3, 0.2, 0.2, 0.2], t_io=1e-4, t_h2d=1e-4, t_u=1e-4,
            grad_bytes=[1e6] * L)

    def test_priority_assignment_increases_with_layer(self):
        g = build_ssgd_dag(self._comm_bound_costs(), 2, PRIORITY,
                           n_iterations=1)
        comms = sorted((t for t in g.tasks.values()
                        if t.channel == NET_CHANNEL),
                       key=lambda t: t.layer)
        prios = [t.priority for t in comms]
        assert prios == sorted(prios), \
            "earlier layers must carry smaller (= stronger) priority"

    def test_priority_drains_layer1_before_late_layers(self):
        costs = self._comm_bound_costs()
        res = simulate_policy(costs, 2, PRIORITY, n_iterations=1)
        order = [s.task.layer for s in res.tasks_on(NET_CHANNEL)]
        # layer 4's comm is ready first (backward runs L..1) but once
        # the channel frees, the earliest-needed queued layer wins:
        assert order[0] == 4
        assert order[1:] == sorted(order[1:]), order

    def test_priority_no_worse_than_fifo_on_comm_bound_workload(self):
        costs = self._comm_bound_costs()
        t_prio = simulate_steady(costs, 4, PRIORITY)
        t_fifo = simulate_steady(costs, 4, CAFFE_MPI)
        assert t_prio <= t_fifo + 1e-12

    def test_priority_no_worse_than_fifo_on_paper_workload(self):
        from repro.core.workloads import resolve_workload
        from repro.core.scenarios import resolve_cluster

        s = Scenario("resnet50", "v100-nvlink-ib", 16, "priority")
        tab = resolve_workload(s.workload)
        costs = tab.iteration_costs(resolve_cluster(s), tab.batch_default,
                                    s.n_workers)
        t_prio = simulate_steady(costs, s.n_workers, PRIORITY)
        t_fifo = simulate_steady(costs, s.n_workers, CAFFE_MPI)
        assert t_prio <= t_fifo + 1e-12


class TestSteadyStateEmptySchedule:
    """Satellite: zero update tasks raised IndexError deep in list
    indexing; now a clear ValueError."""

    def test_zero_iterations_raises_value_error(self):
        costs = IterationCosts(t_f=[1.0], t_b=[1.0], t_c=[0.5])
        res = simulate_policy(costs, 2, CAFFE_MPI, n_iterations=0)
        assert res.iteration_times() == []
        with pytest.raises(ValueError, match="no 'update' task"):
            res.steady_iteration_time()

    def test_custom_dag_without_update_raises_value_error(self):
        from repro.core.dag import DAG, TaskKind
        from repro.core.simulator import simulate

        g = DAG()
        g.add_task("lonely", TaskKind.COMPUTE, 1.0, "gpu:0")
        res = simulate(g)
        with pytest.raises(ValueError, match="no 'update' task"):
            res.steady_iteration_time()

    def test_one_iteration_still_works(self):
        costs = IterationCosts(t_f=[1.0], t_b=[1.0], t_c=[0.5])
        res = simulate_policy(costs, 2, CAFFE_MPI, n_iterations=1)
        assert res.steady_iteration_time() > 0


class TestInterconnectFilterNormalization:
    """Satellite: filter(interconnect=None) silently matched nothing
    because rows normalize None -> 'default'."""

    def _result(self):
        return sweep(ScenarioGrid(
            workloads=("alexnet",), clusters=("k80-pcie-10gbe",),
            worker_counts=(4,), policies=("naive",),
            interconnects=(None, "ib-200g")))

    def test_filter_accepts_none(self):
        r = self._result()
        assert len(r.filter(interconnect=None)) == 1
        assert r.filter(interconnect=None) == \
            r.filter(interconnect="default")

    def test_filter_named_preset_unaffected(self):
        r = self._result()
        [row] = r.filter(interconnect="ib-200g")
        assert row["interconnect"] == "ib-200g"

    def test_label_and_row_share_normalizer(self):
        s = Scenario("alexnet", "k80-pcie-10gbe", 4, "naive")
        assert normalize_interconnect(s.interconnect) == "default"
        assert s.label().endswith("/default")
        assert _fast_eval(s)["interconnect"] == "default"
