"""Test-suite bootstrap.

The property tests use `hypothesis`, which is not part of the pinned
build image.  When the real package is importable we use it; otherwise
we install the deterministic mini-shim from ``_mini_hypothesis.py``
under the ``hypothesis`` module name *before* collection, so the test
modules' ``from hypothesis import given, settings, strategies as st``
keeps working unmodified.
"""
from __future__ import annotations

import importlib.util
import pathlib
import sys
import types


def _install_hypothesis_fallback() -> None:
    try:
        import hypothesis  # noqa: F401  (real library wins when present)
        return
    except ModuleNotFoundError:
        pass
    path = pathlib.Path(__file__).with_name("_mini_hypothesis.py")
    spec = importlib.util.spec_from_file_location("_mini_hypothesis", path)
    assert spec is not None and spec.loader is not None
    mini = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mini)

    hyp = types.ModuleType("hypothesis")
    hyp.given = mini.given
    hyp.settings = mini.settings
    hyp.strategies = mini
    hyp.__mini_shim__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = mini


_install_hypothesis_fallback()
