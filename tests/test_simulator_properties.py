"""Hypothesis property tests for the event-driven simulator and the
launch-layer spec builders."""
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import SHAPES, dryrun_matrix, get_config, shape_applies
from repro.core.dag import DAG, IterationCosts, TaskKind, build_ssgd_dag
from repro.core.policies import ALL_POLICIES
from repro.core.simulator import simulate


@st.composite
def random_costs(draw, max_layers=6):
    L = draw(st.integers(1, max_layers))
    pos = st.floats(0.01, 10.0)
    return IterationCosts(
        t_f=draw(st.lists(pos, min_size=L, max_size=L)),
        t_b=draw(st.lists(pos, min_size=L, max_size=L)),
        t_c=draw(st.lists(pos, min_size=L, max_size=L)),
        t_io=draw(pos), t_h2d=draw(pos), t_u=draw(pos))


class TestSimulatorInvariants:
    @settings(max_examples=50, deadline=None)
    @given(random_costs(), st.integers(1, 4),
           st.sampled_from(sorted(ALL_POLICIES)))
    def test_bounds(self, costs, n_workers, polname):
        pol = ALL_POLICIES[polname]
        g = build_ssgd_dag(costs, n_workers, pol, n_iterations=2)
        r = simulate(g)
        cp, _ = g.critical_path()
        # resource-constrained makespan is bounded below by the
        # critical path and above by full serialization
        assert r.makespan >= cp - 1e-9
        assert r.makespan <= g.total_work() + 1e-9
        for ch, busy in r.channel_busy.items():
            assert busy <= r.makespan + 1e-9          # utilization <= 1

    @settings(max_examples=50, deadline=None)
    @given(random_costs(), st.integers(2, 4),
           st.sampled_from(sorted(ALL_POLICIES)))
    def test_precedence_respected(self, costs, n_workers, polname):
        pol = ALL_POLICIES[polname]
        g = build_ssgd_dag(costs, n_workers, pol, n_iterations=2)
        r = simulate(g)
        for tid, preds in g.preds.items():
            for p in preds:
                assert r.schedule[p].finish <= r.schedule[tid].start + 1e-9

    @settings(max_examples=30, deadline=None)
    @given(random_costs(), st.integers(2, 4))
    def test_channel_exclusive(self, costs, n_workers):
        g = build_ssgd_dag(costs, n_workers, ALL_POLICIES["caffe-mpi"],
                           n_iterations=2)
        r = simulate(g)
        by_ch: dict = {}
        for s in r.schedule.values():
            by_ch.setdefault(s.task.channel, []).append(s)
        for items in by_ch.values():
            items.sort(key=lambda s: s.start)
            for a, b in zip(items, items[1:]):
                assert a.finish <= b.start + 1e-9


class TestInputSpecs:
    def test_matrix_size(self):
        m = dryrun_matrix()
        assert len(m) == 33          # 10*3 + 3 long_500k
        assert ("internlm2-20b", "long_500k") not in m
        assert ("rwkv6-1.6b", "long_500k") in m

    @pytest.mark.parametrize("arch,shape", [
        ("internlm2-20b", "train_4k"), ("whisper-tiny", "train_4k"),
        ("llama-3.2-vision-90b", "prefill_32k"),
        ("rwkv6-1.6b", "decode_32k"), ("gemma3-1b", "long_500k")])
    def test_specs_shapes(self, arch, shape):
        from repro.launch.steps import input_specs
        cfg = get_config(arch)
        sh = SHAPES[shape]
        specs = input_specs(cfg, sh)
        if sh.kind == "train":
            assert specs["tokens"].shape == (sh.global_batch, sh.seq_len)
            if cfg.arch_type == "audio":
                assert specs["frames"].shape == (sh.global_batch,
                                                 cfg.encoder_seq, cfg.d_model)
            if cfg.arch_type == "vlm":
                assert specs["images"].shape[1] == cfg.num_image_tokens
        elif sh.kind == "decode":
            assert specs["token"].shape == (sh.global_batch,)
            leaves = jax.tree_util.tree_leaves(specs["cache"])
            assert leaves, "decode needs a cache"
            # windowed 'L' caches never exceed the window
            if cfg.sliding_window:
                import jax as _jax
                from repro.models import transformer as T
                cache = _jax.eval_shape(
                    lambda: T.init_cache(cfg, 1, sh.seq_len))
                k0 = cache["units"]["b0"]["k"]       # first block is 'L'
                assert k0.shape[2] == cfg.sliding_window

    def test_window_cache_invariance(self):
        """long_500k feasibility: gemma3 local layers cache O(window),
        only its 4 global layers carry the 524k sequence."""
        from repro.models import transformer as T
        cfg = get_config("gemma3-1b")
        cache = jax.eval_shape(lambda: T.init_cache(cfg, 1, 524_288))
        unit = cache["units"]
        local = unit["b0"]["k"].shape
        glob = unit["b5"]["k"].shape
        assert local[2] == 512
        assert glob[2] == 524_288


class TestRooflineMath:
    def test_terms_and_dominance(self):
        from benchmarks.bench_roofline import roofline_terms
        rec = {"n_devices": 256,
               "analytic": {"flops": 256 * 197e12, "hbm_bytes": 0.0,
                            "model_flops": 128 * 197e12},
               "collectives": {"total_bytes": 5e9},
               "cost_analysis": {}, "memory": {}}
        t = roofline_terms(rec)
        assert t["compute_s"] == pytest.approx(1.0)
        assert t["collective_s"] == pytest.approx(0.1)
        assert t["dominant"] == "compute"
        assert t["mfu_at_bound"] == pytest.approx(0.5)
