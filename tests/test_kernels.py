"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in
interpret mode (CPU executes the kernel bodies in Python)."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from repro.kernels.chunked_attention import chunked_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rglru import rglru
from repro.kernels.wkv6 import wkv6

KEY = jax.random.PRNGKey(0)


def _tol(dt):
    return 3e-2 if dt == jnp.bfloat16 else 2e-4


class TestFlashAttention:
    @pytest.mark.parametrize("B,S,H,K,hd,bq,bk", [
        (2, 256, 4, 2, 64, 128, 128),
        (1, 256, 4, 1, 128, 64, 64),
        (1, 128, 8, 8, 64, 128, 32),
        (2, 512, 2, 1, 64, 128, 256),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_causal_sweep(self, B, S, H, K, hd, bq, bk, dtype):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
        k = jax.random.normal(ks[1], (B, S, K, hd), dtype)
        v = jax.random.normal(ks[2], (B, S, K, hd), dtype)
        out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                              interpret=True)
        want = ref.attention(q, k, v, causal=True)
        assert out.shape == want.shape
        err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                    - want.astype(jnp.float32))))
        assert err < _tol(dtype)

    @pytest.mark.parametrize("window", [32, 100, 511])
    def test_sliding_window(self, window):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 512, 4, 64))
        k = jax.random.normal(ks[1], (1, 512, 2, 64))
        v = jax.random.normal(ks[2], (1, 512, 2, 64))
        out = flash_attention(q, k, v, causal=True, window=window,
                              block_q=128, block_k=128, interpret=True)
        want = ref.attention(q, k, v, causal=True, window=window)
        assert float(jnp.max(jnp.abs(out - want))) < 2e-4

    def test_rejects_misaligned(self):
        q = jnp.zeros((1, 100, 2, 64))
        with pytest.raises(ValueError):
            flash_attention(q, q[:, :, :2], q[:, :, :2], block_q=64,
                            block_k=64, interpret=True)


class TestWKV6:
    @pytest.mark.parametrize("B,S,H,hd,bt", [
        (2, 128, 2, 64, 64), (1, 256, 4, 64, 64), (1, 64, 1, 32, 32),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, B, S, H, hd, bt, dtype):
        ks = jax.random.split(KEY, 5)
        r = jax.random.normal(ks[0], (B, S, H, hd), dtype) * 0.5
        k = jax.random.normal(ks[1], (B, S, H, hd), dtype) * 0.5
        v = jax.random.normal(ks[2], (B, S, H, hd), dtype)
        w = jnp.exp(-jnp.exp(
            jax.random.normal(ks[3], (B, S, H, hd)) - 3.0)).astype(dtype)
        u = jax.random.normal(ks[4], (H, hd), jnp.float32) * 0.3
        out, st = wkv6(r, k, v, w, u, block_t=bt, interpret=True)
        want, wst = ref.wkv6(r, k, v, w, u)
        err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                    - want.astype(jnp.float32))))
        assert err < (6e-2 if dtype == jnp.bfloat16 else 1e-3)
        assert float(jnp.max(jnp.abs(st - wst))) < 1e-3

    def test_carried_state_equals_one_shot(self):
        """Chunked decode: running two halves with carried state must
        equal the full-sequence scan (serving correctness)."""
        ks = jax.random.split(KEY, 5)
        B, S, H, hd = 1, 128, 2, 64
        r = jax.random.normal(ks[0], (B, S, H, hd)) * 0.5
        k = jax.random.normal(ks[1], (B, S, H, hd)) * 0.5
        v = jax.random.normal(ks[2], (B, S, H, hd))
        w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, S, H, hd)) - 3.0))
        u = jax.random.normal(ks[4], (H, hd)) * 0.3
        full, s_full = wkv6(r, k, v, w, u, block_t=64, interpret=True)
        h = S // 2
        o1, s1 = wkv6(r[:, :h], k[:, :h], v[:, :h], w[:, :h], u,
                      block_t=64, interpret=True)
        o2, s2 = wkv6(r[:, h:], k[:, h:], v[:, h:], w[:, h:], u, state=s1,
                      block_t=64, interpret=True)
        assert float(jnp.max(jnp.abs(jnp.concatenate([o1, o2], 1) - full))) < 1e-4
        assert float(jnp.max(jnp.abs(s2 - s_full))) < 1e-4


class TestRGLRU:
    @pytest.mark.parametrize("B,S,W,bt,bw", [
        (2, 128, 128, 128, 128), (1, 256, 256, 64, 128), (2, 64, 128, 32, 64),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, B, S, W, bt, bw, dtype):
        ks = jax.random.split(KEY, 4)
        x = jax.random.normal(ks[0], (B, S, W), dtype)
        r = jax.random.normal(ks[1], (B, S, W), dtype)
        i = jax.random.normal(ks[2], (B, S, W), dtype)
        lam = jnp.linspace(0.1, 2.0, W)
        out, h = rglru(x, r, i, lam, block_t=bt, block_w=bw, interpret=True)
        want, wh = ref.rglru(x, r, i, lam)
        err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                    - want.astype(jnp.float32))))
        assert err < _tol(dtype)
        assert float(jnp.max(jnp.abs(h - wh))) < _tol(dtype)

    def test_carried_state(self):
        ks = jax.random.split(KEY, 4)
        B, S, W = 1, 128, 128
        x = jax.random.normal(ks[0], (B, S, W))
        r = jax.random.normal(ks[1], (B, S, W))
        i = jax.random.normal(ks[2], (B, S, W))
        lam = jnp.linspace(0.1, 2.0, W)
        full, h_full = rglru(x, r, i, lam, block_t=64, interpret=True)
        o1, h1 = rglru(x[:, :64], r[:, :64], i[:, :64], lam, block_t=64,
                       interpret=True)
        o2, h2 = rglru(x[:, 64:], r[:, 64:], i[:, 64:], lam, h0=h1,
                       block_t=64, interpret=True)
        assert float(jnp.max(jnp.abs(jnp.concatenate([o1, o2], 1) - full))) < 1e-5
        assert float(jnp.max(jnp.abs(h2 - h_full))) < 1e-5


class TestChunkedAttention:
    """The production flash-schedule path (custom VJP)."""

    @pytest.mark.parametrize("window", [None, 96])
    def test_fwd_and_grad(self, window):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 256, 4, 32))
        k = jax.random.normal(ks[1], (1, 256, 2, 32))
        v = jax.random.normal(ks[2], (1, 256, 2, 32))
        out = chunked_attention(q, k, v, True, window, 64, 64)
        want = ref.attention(q, k, v, causal=True, window=window)
        assert float(jnp.max(jnp.abs(out - want))) < 1e-4

        f = lambda *a: jnp.sum(jnp.sin(chunked_attention(*a, True, window, 64, 64)))
        g = lambda *a: jnp.sum(jnp.sin(ref.attention(*a, causal=True, window=window)))
        gc = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(g, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(gc, gr):
            assert float(jnp.max(jnp.abs(a - b))) < 1e-4


class TestDecodePartials:
    def test_sharded_combine_identity(self):
        """Combining per-shard flash partials equals full attention —
        the math behind the seq-sharded 500k decode."""
        ks = jax.random.split(KEY, 3)
        B, S, H, K, hd = 2, 64, 4, 2, 32
        q = jax.random.normal(ks[0], (B, 1, H, hd))
        k = jax.random.normal(ks[1], (B, S, K, hd))
        v = jax.random.normal(ks[2], (B, S, K, hd))
        valid = jnp.arange(S) <= 37
        want = ref.decode_attention(q, k, v, valid)
        # two shards
        o1, m1, l1 = ref.decode_attention_partials(q, k[:, :32], v[:, :32],
                                                   valid[:32])
        o2, m2, l2 = ref.decode_attention_partials(q, k[:, 32:], v[:, 32:],
                                                   valid[32:])
        m = jnp.maximum(m1, m2)
        o = o1 * jnp.exp(m1 - m)[..., None] + o2 * jnp.exp(m2 - m)[..., None]
        l = l1 * jnp.exp(m1 - m) + l2 * jnp.exp(m2 - m)
        got = o / jnp.maximum(l, 1e-30)[..., None]
        assert float(jnp.max(jnp.abs(got - want))) < 1e-5
