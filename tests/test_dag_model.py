"""Core DAG model: construction, simulation, and the paper's Eqs 1-6."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import analytical as A
from repro.core.dag import DAG, IterationCosts, TaskKind, build_ssgd_dag
from repro.core.policies import (ALL_POLICIES, BUCKETED_25MB, CAFFE_MPI, CNTK,
                                 MXNET, NAIVE, Policy, get_policy)
from repro.core.simulator import simulate

COSTS = IterationCosts(
    t_f=[3.0, 4.0, 5.0], t_b=[6.0, 5.0, 4.0], t_c=[2.0, 3.0, 7.0],
    t_io=2.0, t_h2d=1.0, t_u=0.5, grad_bytes=[10e6, 20e6, 70e6])

EQ3_POLICY = Policy("eq3", overlap_io=True, h2d_early=True)


def steady(costs, n_workers, policy, iters=6):
    g = build_ssgd_dag(costs, n_workers, policy, n_iterations=iters)
    return simulate(g).steady_iteration_time()


class TestDAG:
    def test_cycle_detection(self):
        g = DAG()
        a = g.add_task("a", TaskKind.COMPUTE, 1.0, "gpu:0")
        b = g.add_task("b", TaskKind.COMPUTE, 1.0, "gpu:0")
        g.add_edge(a, b)
        g.add_edge(b, a)
        with pytest.raises(ValueError, match="cycle"):
            g.topo_order()

    def test_negative_duration_rejected(self):
        g = DAG()
        with pytest.raises(ValueError):
            g.add_task("bad", TaskKind.COMPUTE, -1.0, "gpu:0")

    def test_fig1_structure(self):
        """3 layers, 4 workers, one iteration: Fig. 1 has 36 tasks
        (4 io + 4 h2d + 12 fwd + 12 bwd + 3 comm + 1 update)."""
        g = build_ssgd_dag(COSTS, 4, CAFFE_MPI, n_iterations=1)
        assert len(g) == 36
        kinds = [t.kind for t in g.tasks.values()]
        assert kinds.count(TaskKind.COMM) == 4 + 4 + 3
        assert kinds.count(TaskKind.COMPUTE) == 12 + 12 + 1

    def test_single_gpu_no_comm(self):
        c = IterationCosts(t_f=COSTS.t_f, t_b=COSTS.t_b, t_c=[0.0] * 3,
                           t_io=2.0, t_h2d=1.0, t_u=0.5)
        g = build_ssgd_dag(c, 1, NAIVE, n_iterations=1)
        assert not [t for t in g.tasks.values()
                    if t.kind == TaskKind.COMM and t.channel == "net"]

    def test_critical_path_lower_bounds_makespan(self):
        g = build_ssgd_dag(COSTS, 4, CAFFE_MPI, n_iterations=3)
        cp, path = g.critical_path()
        r = simulate(g)
        assert r.makespan >= cp - 1e-9
        assert len(path) >= 2


class TestAnalyticalEquivalence:
    """The simulator reproduces Eqs 1/2/3/5 exactly on matching DAGs."""

    def test_eq1_single_gpu(self):
        c = IterationCosts(t_f=COSTS.t_f, t_b=COSTS.t_b, t_c=[0.0] * 3,
                           t_io=2.0, t_h2d=1.0, t_u=0.5)
        assert steady(c, 1, NAIVE) == pytest.approx(A.eq1_sgd_iteration(c))

    def test_eq2_naive(self):
        assert steady(COSTS, 4, NAIVE) == pytest.approx(A.eq2_naive_ssgd(COSTS))

    def test_eq3_io_overlap(self):
        assert steady(COSTS, 4, EQ3_POLICY) == pytest.approx(A.eq3_io_overlap(COSTS))

    def test_eq5_wfbp(self):
        assert steady(COSTS, 4, CAFFE_MPI) == pytest.approx(A.eq5_wfbp(COSTS))

    @settings(max_examples=60, deadline=None)
    @given(st.data())
    def test_property_eqs_match_simulator(self, data):
        L = data.draw(st.integers(1, 8))
        pos = st.floats(0.01, 20.0)
        t_f = data.draw(st.lists(pos, min_size=L, max_size=L))
        t_b = data.draw(st.lists(pos, min_size=L, max_size=L))
        t_c = data.draw(st.lists(pos, min_size=L, max_size=L))
        t_io = data.draw(pos)
        t_h2d = data.draw(pos)
        c = IterationCosts(t_f=t_f, t_b=t_b, t_c=t_c, t_io=t_io,
                           t_h2d=t_h2d, t_u=data.draw(pos))
        n = data.draw(st.integers(2, 5))
        assert steady(c, n, NAIVE, 5) == pytest.approx(A.eq2_naive_ssgd(c))
        assert steady(c, n, EQ3_POLICY, 8) == pytest.approx(A.eq3_io_overlap(c))
        assert steady(c, n, CAFFE_MPI, 8) == pytest.approx(A.eq5_wfbp(c))

    @settings(max_examples=60, deadline=None)
    @given(st.data())
    def test_tc_no_bounds(self, data):
        L = data.draw(st.integers(1, 10))
        pos = st.floats(0.0, 10.0)
        t_b = data.draw(st.lists(pos, min_size=L, max_size=L))
        t_c = data.draw(st.lists(pos, min_size=L, max_size=L))
        tc_no = A.non_overlapped_comm(t_b, t_c)
        assert -1e-9 <= tc_no <= sum(t_c) + 1e-9
        # the last layer's comm can never be hidden
        if all(c == 0 for c in t_c[1:]) and t_c[0] > 0:
            assert tc_no == pytest.approx(t_c[0])


class TestPolicyOrdering:
    def test_paper_framework_ranking(self):
        """Caffe-MPI <= MXNet/TF <= CNTK <= naive (paper Fig. 2/3)."""
        t = {name: steady(COSTS, 4, p, 8)
             for name, p in ALL_POLICIES.items()}
        assert t["caffe-mpi"] <= t["mxnet"] + 1e-9
        assert t["mxnet"] <= t["cntk"] + 1e-9
        assert t["cntk"] <= t["naive"] + 1e-9

    @settings(max_examples=40, deadline=None)
    @given(st.data())
    def test_overlap_never_hurts(self, data):
        L = data.draw(st.integers(1, 6))
        pos = st.floats(0.01, 10.0)
        c = IterationCosts(
            t_f=data.draw(st.lists(pos, min_size=L, max_size=L)),
            t_b=data.draw(st.lists(pos, min_size=L, max_size=L)),
            t_c=data.draw(st.lists(pos, min_size=L, max_size=L)),
            t_io=data.draw(pos), t_h2d=data.draw(pos), t_u=data.draw(pos))
        n = data.draw(st.integers(2, 4))
        assert steady(c, n, CAFFE_MPI, 8) <= steady(c, n, CNTK, 8) + 1e-9
        assert steady(c, n, CNTK, 8) <= steady(c, n, NAIVE, 8) + 1e-9

    def test_bucketing_reduces_comm_when_latency_bound(self):
        """Many tiny tensors: per-layer collectives pay L alphas, one
        bucket pays one (the paper's 9.6%-utilization problem)."""
        from repro.core.hardware import V100_CLUSTER
        from repro.core.costmodel import comm_scale_fn
        L = 50
        # backward far too short to hide the 50 per-layer alphas
        c = IterationCosts(t_f=[1e-4] * L, t_b=[1e-4] * L,
                           t_c=[V100_CLUSTER.allreduce_time(40_000, 16)] * L,
                           t_io=0.0, t_h2d=0.0, t_u=0.0,
                           grad_bytes=[40_000] * L)
        scale = comm_scale_fn(V100_CLUSTER, 16)
        g_layer = build_ssgd_dag(c, 4, CAFFE_MPI, 6, comm_scale=scale)
        g_bucket = build_ssgd_dag(c, 4, BUCKETED_25MB, 6, comm_scale=scale)
        t_layer = simulate(g_layer).steady_iteration_time()
        t_bucket = simulate(g_bucket).steady_iteration_time()
        assert t_bucket < t_layer

    def test_get_policy_unknown(self):
        with pytest.raises(KeyError):
            get_policy("nccl")


class TestSimulator:
    def test_channel_serialization(self):
        g = DAG()
        a = g.add_task("a", TaskKind.COMPUTE, 2.0, "gpu:0")
        b = g.add_task("b", TaskKind.COMPUTE, 2.0, "gpu:0")
        r = simulate(g)
        assert r.makespan == pytest.approx(4.0)
        assert r.utilization("gpu:0") == pytest.approx(1.0)

    def test_parallel_channels(self):
        g = DAG()
        g.add_task("a", TaskKind.COMPUTE, 2.0, "gpu:0")
        g.add_task("b", TaskKind.COMPUTE, 2.0, "gpu:1")
        assert simulate(g).makespan == pytest.approx(2.0)

    def test_priority_channel_reorders(self):
        g = DAG()
        gate = g.add_task("gate", TaskKind.COMPUTE, 1.0, "gpu:0")
        lo = g.add_task("lo", TaskKind.COMM, 5.0, "net", priority=2.0)
        hi = g.add_task("hi", TaskKind.COMM, 1.0, "net", priority=1.0)
        g.add_edge(gate, lo)
        g.add_edge(gate, hi)
        fifo = simulate(g)
        prio = simulate(g, priority_channels=frozenset(["net"]))
        # under priority scheduling 'hi' runs first
        assert prio.schedule[hi].start <= prio.schedule[lo].start
        assert prio.schedule[hi].finish <= fifo.schedule[hi].finish
