"""Bucket-timeline batched path (ISSUE 4): the (S, B) kernel vs the
event-driven oracle on every built-in grid, degenerate bucket sizes,
PRIORITY <= FIFO on the batched path, and the incremental / auto-steady
simulator."""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from strategies import BUCKET_BYTES_CHOICES, iteration_costs

from repro.core import analytical as A
from repro.core import bucketsim
from repro.core.dag import (IterationCosts, SSGDDagBuilder, _bucketize,
                            build_ssgd_dag)
from repro.core.policies import (ALL_POLICIES, BUCKETED_25MB, CAFFE_MPI,
                                 PRIORITY, Policy, get_policy)
from repro.core.scenarios import (Scenario, ScenarioGrid, default_grid,
                                  frontier_grid, mixed_grid, resolve_cluster)
from repro.core.simulator import (Simulation, simulate, simulate_policy,
                                  simulate_steady)
from repro.core.sweep import _sim_eval, sweep
from repro.core.workloads import resolve_workload

TIMELINE_POLICIES = ("bucketed-1mb", "bucketed-4mb", "bucketed-25mb",
                     "bucketed-100mb", "priority")


class TestBucketStructure:
    @settings(max_examples=100, deadline=None)
    @given(iteration_costs(with_comm=True),
           st.sampled_from(BUCKET_BYTES_CHOICES))
    def test_matches_dag_bucketize(self, costs, beta):
        """bucket_layers mirrors the DAG builder's boundaries exactly:
        same payload sums, same release (earliest-member) layers.
        (with_comm puts t_c > 0 exactly where grad_bytes > 0, as in
        iteration_costs.)"""
        pol = Policy("x", overlap_comm=True, bucket_bytes=beta)
        want = [(sum(costs.grad_bytes[m] for m in members), members[-1])
                for _, members, _ in _bucketize(costs, pol, None)]
        got = bucketsim.bucket_layers(costs.grad_bytes, beta)
        assert len(got) == len(want)
        for (gb, gl), (wb, wl) in zip(got, want):
            assert gb == pytest.approx(wb) and gl == wl

    def test_table_pads_ragged_workloads(self):
        grad = np.array([[1e6, 0.0, 2e6], [5e6, 5e6, 5e6]])
        bt = bucketsim.bucket_table(grad, 4e6)
        assert bt.nbytes.shape == bt.mask.shape == bt.release_layer.shape
        # row 0: 2e6 + 1e6 never reach 4e6 -> one trailing bucket of
        # 3e6; row 1: every 5e6 layer flushes alone -> three buckets
        assert bt.mask.sum(axis=1).tolist() == [1, 3]
        assert bt.nbytes[0, 0] == pytest.approx(3e6)
        assert bt.release_layer[0, 0] == 0
        assert bt.nbytes[1].tolist() == pytest.approx([5e6, 5e6, 5e6])
        assert bt.release_layer[1].tolist() == [2, 1, 0]


class TestTimelineResidual:
    @settings(max_examples=100, deadline=None)
    @given(iteration_costs(with_comm=True))
    def test_per_layer_buckets_reduce_to_wfbp_residual(self, costs):
        """bucket_bytes smaller than every layer payload ≡ per-layer
        WFBP: the residual is exactly non_overlapped_comm_batch."""
        t_b = np.asarray(costs.t_b)[None, :]
        t_c = np.asarray(costs.t_c)[None, :]
        grad = np.asarray(costs.grad_bytes)[None, :]
        bt = bucketsim.bucket_table(grad, 1.0)       # 1 byte: never fuses
        # gather this workload's per-layer comm times into bucket order
        dur = np.where(bt.mask, t_c[0][bt.release_layer], 0.0)
        got = bucketsim.timeline_residual(
            t_b, dur, bt.release_layer, bt.mask)[0]
        want = A.non_overlapped_comm_batch(t_b, t_c)[0]
        assert got == pytest.approx(want, rel=1e-12, abs=1e-15)

    def test_single_bucket_with_layer1_comm_is_comm_at_end(self):
        """One giant bucket whose earliest member is layer 1 releases
        when backward finishes — the residual is the full fused
        collective, i.e. comm-at-end."""
        t_b = np.array([[2.0, 1.0, 3.0]])
        grad = np.array([[4e6, 0.0, 8e6]])
        bt = bucketsim.bucket_table(grad, 1e9)       # never flushes early
        assert bt.mask.sum() == 1 and bt.release_layer[0, 0] == 0
        dur = np.array([[5.0]])
        got = bucketsim.timeline_residual(t_b, dur, bt.release_layer,
                                          bt.mask)[0]
        assert got == pytest.approx(5.0)
        # and with overlap_comm=False the release is total_b regardless
        got_no = bucketsim.timeline_residual(
            t_b, dur, bt.release_layer, bt.mask, overlap_comm=False)[0]
        assert got_no == pytest.approx(5.0)

    def test_no_comm_and_padding_neutral(self):
        t_b = np.ones((3, 4))
        bt = bucketsim.bucket_table(np.zeros((3, 4)), 25e6)
        dur = np.zeros((3, bt.n_buckets))
        z = bucketsim.timeline_residual(t_b, dur, bt.release_layer, bt.mask)
        assert (z == 0.0).all()


def _grid_oracle_check(grid, stride, rel=1e-6):
    """Batched timeline rows vs the event-driven oracle, sampled with a
    coprime stride so every axis value is covered."""
    r = sweep(grid)
    assert r.n_simulated == 0
    scenarios = grid.expand()
    checked = 0
    for i in range(0, len(scenarios), stride):
        row = r.rows[i]
        if row["method"] != "timeline":
            continue
        ref = _sim_eval(scenarios[i])
        for k in ("iteration_time_s", "samples_per_sec", "speedup",
                  "t_comm_s", "t_comp_s"):
            assert row[k] == pytest.approx(ref[k], rel=rel), \
                (scenarios[i].label(), k)
        checked += 1
    assert checked > 0


class TestBuiltinGridAgreement:
    """ISSUE-4 acceptance: batched bucketed/priority evaluation agrees
    with simulate_steady to <= 1e-6 relative on every built-in grid
    (default and mixed swept with the timeline policy axis swapped in,
    frontier carrying it natively)."""

    def test_default_grid_timeline_policies(self):
        grid = dataclasses.replace(default_grid(),
                                   policies=TIMELINE_POLICIES)
        _grid_oracle_check(grid, stride=13)

    def test_mixed_grid_timeline_policies(self):
        grid = dataclasses.replace(mixed_grid(), policies=TIMELINE_POLICIES)
        _grid_oracle_check(grid, stride=101)

    def test_frontier_grid_native(self):
        _grid_oracle_check(frontier_grid(), stride=2999)

    def test_trace_workload_timeline(self):
        grid = ScenarioGrid(workloads=("trace:alexnet-k80",),
                            clusters=("v100-nvlink-ib",),
                            worker_counts=(2, 8), policies=TIMELINE_POLICIES)
        _grid_oracle_check(grid, stride=1)


class TestPriorityOnBatchedPath:
    def test_priority_no_worse_than_fifo(self):
        """PRIORITY <= per-layer FIFO WFBP, preserved on the batched
        path (in fact equal: the net channel is work-conserving, so
        reordering never delays the last comm finish)."""
        grid = ScenarioGrid(worker_counts=(2, 4, 16, 32),
                            policies=("caffe-mpi", "priority"),
                            collectives=("ring", "tree", "hierarchical"))
        r = sweep(grid)
        fifo = r.filter(policy="caffe-mpi")
        prio = r.filter(policy="priority")
        assert len(fifo) == len(prio) > 0
        for a, b in zip(prio, fifo):
            assert a["iteration_time_s"] <= b["iteration_time_s"] * (1 + 1e-12)
            assert a["iteration_time_s"] == pytest.approx(
                b["iteration_time_s"], rel=1e-9)


class TestDegenerateScenarios:
    def test_one_giant_bucket_equals_fused_comm_at_end(self):
        """googlenet (~28 MB of gradients) under bucketed-100mb: one
        bucket, released by layer-1's backward (conv1 has params), so
        t_iter = max(io+h2d, comp + fused_allreduce + t_u)."""
        s = Scenario("googlenet", "v100-nvlink-ib", 16, "bucketed-100mb")
        tab = resolve_workload(s.workload)
        assert float(tab.grad_bytes.sum()) < 100e6
        assert tab.grad_bytes[0] > 0
        cluster = resolve_cluster(s)
        costs = tab.iteration_costs(cluster, tab.batch_default, 16)
        dur = cluster.allreduce_time(float(tab.grad_bytes.sum()), 16)
        want = max(costs.t_io + costs.t_h2d,
                   float(np.sum(costs.t_f) + np.sum(costs.t_b))
                   + dur + costs.t_u)
        [row] = sweep(ScenarioGrid(
            workloads=("googlenet",), clusters=("v100-nvlink-ib",),
            worker_counts=(16,), policies=("bucketed-100mb",))).rows
        assert row["method"] == "timeline"
        assert row["iteration_time_s"] == pytest.approx(want, rel=1e-12)

    def test_one_byte_buckets_equal_per_layer_wfbp(self):
        """bucket_bytes below every layer payload ≡ caffe-mpi's exact
        per-layer closed form."""
        from repro.core import policies as P
        P.ALL_POLICIES["_bucket1b"] = Policy(
            "_bucket1b", overlap_io=True, h2d_early=True, overlap_comm=True,
            bucket_bytes=1.0)
        try:
            grid = ScenarioGrid(workloads=("alexnet", "resnet50"),
                                clusters=("v100-nvlink-ib",),
                                worker_counts=(4, 16),
                                policies=("_bucket1b", "caffe-mpi"))
            r = sweep(grid)
            b1 = r.filter(policy="_bucket1b")
            cm = r.filter(policy="caffe-mpi")
            for a, b in zip(b1, cm):
                assert a["method"] == "timeline" and b["method"] == "analytical"
                assert a["iteration_time_s"] == pytest.approx(
                    b["iteration_time_s"], rel=1e-12)
        finally:
            del P.ALL_POLICIES["_bucket1b"]

    def test_zero_comm_single_worker(self):
        """n_workers=1: no comm tasks at all; every timeline policy
        collapses to the zero-comm pipeline (speedup 1.0)."""
        grid = ScenarioGrid(workloads=("alexnet",),
                            clusters=("k80-pcie-10gbe",), worker_counts=(1,),
                            policies=TIMELINE_POLICIES + ("caffe-mpi",))
        r = sweep(grid)
        times = {row["policy"]: row["iteration_time_s"] for row in r.rows}
        for name in TIMELINE_POLICIES:
            assert times[name] == pytest.approx(times["caffe-mpi"],
                                                rel=1e-12)
        for row in r.rows:
            assert row["speedup"] == pytest.approx(1.0)
            assert row["t_comm_s"] == 0.0

    def test_single_layer_workload(self):
        from repro.traces.format import LayerRecord, Trace
        import repro.traces.bundled as bundled
        from repro.core.workloads import clear_workload_cache

        trace = Trace(network="one", cluster="y", iterations=(
            (LayerRecord(0, "conv1", 30_000.0, 60_000.0, 0.0, 4e6),),),
            batch_per_gpu=16)
        bundled.BUNDLED_TRACES["_single_layer"] = trace
        try:
            clear_workload_cache()
            grid = ScenarioGrid(workloads=("trace:_single_layer",),
                                clusters=("v100-nvlink-ib",),
                                worker_counts=(1, 2, 8),
                                policies=TIMELINE_POLICIES)
            _grid_oracle_check(grid, stride=1)
        finally:
            del bundled.BUNDLED_TRACES["_single_layer"]
            clear_workload_cache()


class TestIncrementalSimulator:
    """Satellite: the heap-based scheduler and the one-iteration-at-a-
    time extension produce exactly the monolithic schedule."""

    @settings(max_examples=40, deadline=None)
    @given(iteration_costs(max_layers=6), st.integers(1, 4),
           st.sampled_from(sorted(ALL_POLICIES)), st.integers(1, 4))
    def test_incremental_matches_monolithic(self, costs, n, pol_name, iters):
        pol = ALL_POLICIES[pol_name]
        g = build_ssgd_dag(costs, n, pol, n_iterations=iters)
        prio = frozenset(["net"]) if pol.priority_comm else None
        mono = simulate(g, prio)
        inc = simulate_policy(costs, n, pol, n_iterations=iters)
        assert len(mono.schedule) == len(inc.schedule)
        for tid, s in mono.schedule.items():
            assert inc.schedule[tid].start == s.start
            assert inc.schedule[tid].finish == s.finish

    @settings(max_examples=5, deadline=None)
    @given(iteration_costs(max_layers=3))
    def test_extend_requires_run_between_iterations(self, costs):
        b = SSGDDagBuilder(costs, 2, CAFFE_MPI)
        sim = Simulation(b.dag)
        b.add_iteration()
        assert sim.extend() > 0
        sim.run()
        assert sim.result().makespan > 0


class TestAutoSteady:
    @settings(max_examples=30, deadline=None)
    @given(iteration_costs(max_layers=8), st.integers(1, 4),
           st.sampled_from(sorted(ALL_POLICIES)))
    def test_auto_steady_matches_full_warmup(self, costs, n, pol_name):
        pol = ALL_POLICIES[pol_name]
        full = simulate_policy(costs, n, pol, n_iterations=8) \
            .steady_iteration_time()
        auto = simulate_steady(costs, n, pol, n_iterations=8)
        assert auto == pytest.approx(full, rel=1e-9)

    def test_n_iterations_used_exposed_and_capped(self):
        costs = IterationCosts(t_f=[1.0, 1.0], t_b=[1.0, 1.0],
                               t_c=[0.1, 0.1], t_io=0.1, t_h2d=0.1, t_u=0.1,
                               grad_bytes=[1e6, 1e6])
        full = simulate_policy(costs, 2, CAFFE_MPI, n_iterations=6)
        assert full.n_iterations_used == 6
        auto = simulate_policy(costs, 2, CAFFE_MPI, n_iterations=6,
                               auto_steady=True)
        assert 1 <= auto.n_iterations_used <= 6
        assert auto.n_iterations_used < 6     # this pipeline settles fast
        assert auto.steady_iteration_time() == pytest.approx(
            full.steady_iteration_time(), rel=1e-9)

    @settings(max_examples=5, deadline=None)
    @given(iteration_costs(max_layers=4))
    def test_cap_respected_when_not_converged(self, costs):
        # io-bound pipeline with a long transient still stops at the cap
        res = simulate_policy(costs, 3, get_policy("mxnet"),
                              n_iterations=2, auto_steady=True)
        assert res.n_iterations_used <= 2


class TestRoutingPredicates:
    def test_timeline_form_covers_bucketed_and_priority(self):
        for name, pol in ALL_POLICIES.items():
            fast = A.has_closed_form(pol)
            tl = A.has_timeline_form(pol)
            assert not (fast and tl), name      # disjoint
            assert fast or tl, name             # all built-ins batched
            if pol.bucket_bytes or pol.priority_comm:
                assert tl, name

    def test_unstudied_combination_has_neither_form(self):
        weird = Policy("w", overlap_comm=True, bucket_bytes=25e6)
        assert not A.has_closed_form(weird)
        assert not A.has_timeline_form(weird)

    def test_bucket_size_policy_family_registered(self):
        for mb in (1, 4, 25, 100):
            pol = get_policy(f"bucketed-{mb}mb")
            assert pol.bucket_bytes == pytest.approx(mb * 1e6)
            assert A.has_timeline_form(pol)

    def test_frontier_grid_carries_timeline_axis(self):
        g = frontier_grid()
        assert len(g) == len(g.expand()) == 51_840
        for name in TIMELINE_POLICIES:
            assert name in g.policies


class TestBucketSizeOrdering:
    def test_fusion_amortizes_latency_on_paper_workload(self):
        """On latency-dominated InfiniBand (the paper's 9.6% problem),
        bigger buckets strictly reduce total comm; the sweet spot in
        iteration time may sit in between (overlap lost)."""
        grid = ScenarioGrid(workloads=("resnet50",),
                            clusters=("v100-nvlink-ib",), worker_counts=(16,),
                            policies=("caffe-mpi", "bucketed-1mb",
                                      "bucketed-25mb", "bucketed-100mb"))
        r = sweep(grid)
        t = {row["policy"]: row["iteration_time_s"] for row in r.rows}
        # 25 MB buckets beat per-layer WFBP on this workload/cluster
        assert t["bucketed-25mb"] < t["caffe-mpi"]
