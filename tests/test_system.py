"""End-to-end behaviour: real training runs on CPU with the full
substrate (pipeline -> model -> policy -> optimizer -> checkpoint),
plus serving."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import restore_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.configs.shapes import TRAIN_4K
from repro.data.pipeline import PrefetchLoader, SyntheticLMDataset
from repro.models import transformer as T
from repro.optim.sgd import adamw


@pytest.fixture(scope="module")
def tiny_cfg():
    return get_config("qwen1.5-4b").reduced(num_layers=2, d_model=64,
                                            num_heads=2, d_ff=128,
                                            vocab_size=128)


def test_training_reduces_loss(tiny_cfg):
    cfg = tiny_cfg
    key = jax.random.PRNGKey(0)
    params = T.init_lm(cfg, key)
    opt = adamw(3e-3)
    state = opt.init(params)
    loader = PrefetchLoader(SyntheticLMDataset(cfg.vocab_size, 16, 8, seed=3),
                            depth=2)

    @jax.jit
    def step(params, state, tokens, labels):
        (l, m), g = jax.value_and_grad(
            lambda p: T.loss_fn(cfg, p, tokens, labels), has_aux=True)(params)
        params, state = opt.update(g, state, params)
        return params, state, l

    losses = []
    for i, batch in zip(range(30), loader):
        params, state, l = step(params, state,
                                jnp.asarray(batch["tokens"]),
                                jnp.asarray(batch["labels"]))
        losses.append(float(l))
    loader.close()
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_train_launcher_end_to_end(tmp_path):
    from repro.launch.train import build_argparser, run
    summary_path = tmp_path / "s.json"
    ckpt = tmp_path / "ck.npz"
    args = build_argparser().parse_args([
        "--arch", "gemma3-1b", "--steps", "6", "--batch", "4",
        "--seq", "32", "--policy", "single",
        "--checkpoint", str(ckpt), "--summary-json", str(summary_path)])
    summary = run(args)
    assert summary["steps"] == 6
    assert np.isfinite(summary["loss_last"])
    assert ckpt.exists() and summary_path.exists()


def test_serve_launcher_end_to_end():
    from repro.launch.serve import main
    summary = main(["--arch", "rwkv6-1.6b", "--batch", "2",
                    "--prompt-len", "8", "--gen", "8"])
    assert summary["generated"] == 8
    assert summary["decode_tok_per_s"] > 0


def test_checkpoint_resume_bitwise(tiny_cfg, tmp_path):
    cfg = tiny_cfg
    key = jax.random.PRNGKey(1)
    params = T.init_lm(cfg, key)
    opt = adamw(1e-3)
    state = opt.init(params)
    tokens = jax.random.randint(key, (4, 16), 0, cfg.vocab_size)
    labels = jax.random.randint(key, (4, 16), 0, cfg.vocab_size)

    @jax.jit
    def step(params, state):
        g = jax.grad(lambda p: T.loss_fn(cfg, p, tokens, labels)[0])(params)
        return opt.update(g, state, params)

    for _ in range(3):
        params, state = step(params, state)
    save_checkpoint(tmp_path / "ck.npz", params, state, step=3)
    cont_params, cont_state = params, state
    for _ in range(2):
        cont_params, cont_state = step(cont_params, cont_state)

    r_params, r_state, meta = restore_checkpoint(tmp_path / "ck.npz",
                                                 params, state)
    assert meta["step"] == 3
    for _ in range(2):
        r_params, r_state = step(r_params, r_state)
    for a, b in zip(jax.tree_util.tree_leaves(cont_params),
                    jax.tree_util.tree_leaves(r_params)):
        assert bool(jnp.all(a == b))


def test_dryrun_machinery_on_cpu_mesh():
    """The dry-run path (specs -> shardings -> lower -> compile ->
    analyses) on a 1x1 CPU mesh with a reduced config — the exact code
    path of the 512-device run."""
    from repro.launch import steps as S
    from repro.launch.mesh import make_cpu_mesh
    from repro.models import sharding as shd
    from repro.optim.sgd import sgd

    cfg = get_config("gemma3-1b").reduced(num_layers=2)
    mesh = make_cpu_mesh(1, 1)
    sc = shd.ShardingConfig(mesh_axes=mesh.axis_names, mode="fsdp")
    shd.set_sharding(sc)
    shd.set_mesh_sizes({"data": 1, "model": 1})
    try:
        pshape = S.params_shape(cfg)
        pspecs = shd.named_shardings(pshape, sc, mesh)
        opt = sgd(1e-2, momentum=0.9)
        oshape = jax.eval_shape(opt.init, pshape)
        ospecs = shd.named_shardings(oshape, sc, mesh)
        shape = dataclasses.replace(TRAIN_4K, seq_len=32, global_batch=4)
        specs = S.input_specs(cfg, shape)
        step = S.make_train_step(cfg, opt, remat=True)
        from repro.launch.mesh import activate_mesh
        with activate_mesh(mesh):
            lowered = jax.jit(step, in_shardings=(pspecs, ospecs, None)) \
                .lower(pshape, oshape, specs)
            compiled = lowered.compile()
        assert compiled.cost_analysis() is not None
    finally:
        shd.set_sharding(None)
        shd.set_mesh_sizes(None)
